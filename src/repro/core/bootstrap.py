"""Bootstrap engines (paper §3, DESIGN.md §2).

A resample-with-replacement is represented by a *weight vector* over the
sample, so ``f(resample)`` is a weighted statistic and the B-resample loop
vectorizes over a dense (B, n) weight matrix — MXU work instead of gathers.

Two engines:

* ``multinomial`` — paper-faithful: the B rows are exact multinomial
  counts Multinomial(n; 1/n,...,1/n), i.e. classic Efron bootstrap.
* ``poisson``     — distributed default (beyond-paper, DESIGN.md §7.1):
  iid Poisson(1) weights per (item, resample).  Same first two moments,
  shard-independent, and makes inter-iteration delta maintenance exact.

Both route moment statistics through kernels/weighted_stats when asked.

Backends (``backend=`` on ``bootstrap``/``bootstrap_chunked``):

* ``None``        — materialized weights (jnp oracle); ``use_kernel`` may
  additionally route the contraction through the weighted_stats kernel.
* ``"fused_rng"`` — matrix-free (poisson engine only): weights are
  generated inside the contraction from a counter-based PRNG, so the (B, n)
  weight matrix never exists.  Statistics opt in via
  ``Statistic.fused_poisson_states``: moment statistics (Mean/Sum/Count/
  Var/Std) route through kernels/weighted_stats.fused_poisson_moments
  (peak O(B·d)), ``KMeansStep`` through
  kernels/kmeans_assign.fused_poisson_kmeans (peak O(B·k·d), and no (n, k)
  distance/one-hot intermediate either), ``Quantile``/``Median`` through
  kernels/weighted_hist.fused_poisson_hist (peak O(B·d·nbins), no
  (n, d, nbins) one-hot either) — every built-in statistic is fused;
  custom statistics without a fused path fall back to materializing the
  same implicit weights per chunk.  The PRNG seed derives deterministically
  from ``key``, so the fold-in discipline (delta maintenance, common random
  numbers) carries over unchanged.  A ``StatisticGroup`` routes through
  kernels/fused_multi: ONE shared weight stream and one pass over x feeds
  every member's accumulator, so a k-statistic session costs ~1× (not k×)
  the RNG and memory traffic — and all members see the same resamples.

Multi-device (``mesh=`` + ``data_axis=`` on the fused backend): the n axis
is sharded over the mesh's data axis with shard_map; each shard runs the
in-kernel weight generation on its local rows with a stream keyed by
``(base_seed, shard_index, chunk)`` via ``offset_seed``, and only the small
per-resample states (``Statistic.psum_state``: (B, d) moments /
(B, k, d) k-means / (B, d, nbins) histograms) cross devices — no weight or
sample traffic ever does.  The paper's Hadoop mapping survives intact:
mapper = shard-local fused update, combiner = ``merge``, reducer = psum of
mergeable states.  ``sharded_fused_states(..., mesh=None, nshards=s)`` runs
the identical decomposition sequentially on one device and is bitwise equal
to the mesh run (XLA's all-reduce and the sequential left-fold merge
reduce in the same shard order) — the single-device path stays the oracle.
"""
from __future__ import annotations

import dataclasses
import inspect
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import accuracy
from repro.core.reduce_api import (Statistic, _as_2d, bind_params,
                                   split_params)


@dataclasses.dataclass
class BootstrapResult:
    estimate: jax.Array        # f on the full sample (unweighted), corrected
    thetas: jax.Array          # (B, ...) bootstrap result distribution
    report: accuracy.AccuracyReport
    B: int
    n: int

    @property
    def cv(self) -> float:
        return self.report.cv


# ----------------------------------------------------------------------------
# weight generation
# ----------------------------------------------------------------------------
def seed_from_key(key: jax.Array) -> jax.Array:
    """Deterministic int32 seed for the counter-based in-kernel PRNG.

    Multi-stream callers (chunked bootstrap, delta maintenance) derive ONE
    base seed per run and offset it by the chunk/step counter via
    ``offset_seed`` — streams within a run are distinct *by construction*
    (no 31-bit birthday bound), while different keys still give independent
    runs."""
    return jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max,
                              dtype=jnp.int32)


_SEED_MOD = int(jnp.iinfo(jnp.int32).max)      # 2^31 - 1


def offset_seed(base_seed, i):
    """The i-th derived stream seed: (base + i) mod (2^31 − 1), computed
    without int32 overflow.

    ``base_seed`` comes from ``seed_from_key`` (∈ [0, 2^31−1)); a plain
    ``base + i`` wraps past ``iinfo(int32).max`` for large chunk/step
    counters (or a base drawn near the boundary), silently flipping the
    seed negative.  Both branches stay inside [0, 2^31−1)."""
    base = jnp.asarray(base_seed, jnp.int32)
    off = jnp.asarray(i, jnp.int32) % _SEED_MOD
    room = _SEED_MOD - off
    return jnp.where(base >= room, base - room, base + off)


def fused_resample_states(stat: Statistic, seed, x2: jax.Array, B: int,
                          n_valid=None, valid_mask=None):
    """B-leading pytree of per-resample states for ``x2`` under implicit
    in-kernel Poisson(1) weights (the matrix-free hot path).

    Statistics with a fused path (``Statistic.fused_poisson_states``:
    moment statistics and KMeansStep) never see a (B, n) matrix; other
    statistics fall back to materializing the same implicit weights.  The
    result is a *delta* state: ``merge`` it into running states
    (delta/chunked) or ``finalize`` it directly (one-shot bootstrap).

    ``valid_mask`` (traced (n,) f32 of exact 0.0/1.0) multiplies the
    implicit weights — arbitrary interior validity holes.  Custom
    statistics whose ``fused_poisson_states`` predates the kwarg are
    detected by signature and routed to the materialized fallback (same
    implicit weights, mask applied to the matrix) rather than crashing.
    """
    if valid_mask is None:
        states = stat.fused_poisson_states(seed, x2, B, n_valid=n_valid)
    elif "valid_mask" in inspect.signature(
            stat.fused_poisson_states).parameters:
        states = stat.fused_poisson_states(seed, x2, B, n_valid=n_valid,
                                           valid_mask=valid_mask)
    else:
        states = None
    if states is not None:
        return states
    from repro.kernels.weighted_stats import ops as ws_ops
    w = ws_ops.implicit_weights(seed, B, x2.shape[0])
    if n_valid is not None:
        w = w * (jnp.arange(x2.shape[0]) < n_valid).astype(w.dtype)[None, :]
    if valid_mask is not None:
        w = w * jnp.asarray(valid_mask, w.dtype).reshape(1, -1)
    dim = x2.shape[1]
    return jax.vmap(lambda wr: stat.update(stat.init_state(dim), x2, wr))(w)


# ----------------------------------------------------------------------------
# sharded matrix-free path (psum the states, never the weights)
# ----------------------------------------------------------------------------
def _shard_local_states(stat: Statistic, base_seed, x_local: jax.Array,
                        B: int, shard_idx, nshards: int, n_valid_local,
                        chunk: Optional[int] = None, step=0,
                        with_estimate: bool = False):
    """Fused states for ONE shard's local rows.

    The shard's stream seed for local chunk c is
    ``offset_seed(base_seed, (step + c) * nshards + shard_idx)`` — chunk-
    major interleaving: distinct per (shard, chunk) within a call and per
    (shard, step) across delta extends, and an nshards=1 "mesh" reproduces
    the single-device seeds exactly (stream index collapses to the
    chunk/step counter).  ``chunk`` and a nonzero ``step`` are mutually
    exclusive (enforced by ``sharded_fused_states``): combining them would
    alias step s's chunk c+1 stream with step s+1's chunk c stream.
    ``chunk=None`` processes the local rows in one fused call.

    ``with_estimate=True`` additionally folds the shard's rows into ONE
    unweighted (all-ones within n_valid) estimate state in the same pass,
    returning ``(states, est_state)`` — the chunked/streaming drivers'
    single-read estimate (no second pass over the data).
    """
    n_local, dim = x_local.shape
    if chunk is None:
        seed = offset_seed(base_seed, step * nshards + shard_idx)
        states = fused_resample_states(stat, seed, x_local, B,
                                       n_valid=n_valid_local)
        if not with_estimate:
            return states
        vi = (jnp.arange(n_local) < n_valid_local).astype(jnp.float32)
        est = stat.update(stat.init_state(dim), x_local, vi)
        return states, est
    pad = (-n_local) % chunk
    xp = jnp.pad(x_local, ((0, pad), (0, 0)))
    nchunks = xp.shape[0] // chunk
    xc = xp.reshape(nchunks, chunk, dim)
    init = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))
    init_est = stat.init_state(dim)

    def body(carry, c):
        states, est = carry
        nv = jnp.clip(n_valid_local - c * chunk, 0, chunk)
        seed = offset_seed(base_seed, (step + c) * nshards + shard_idx)
        delta = fused_resample_states(stat, seed, xc[c], B, n_valid=nv)
        if with_estimate:
            vi = (jnp.arange(chunk) < nv).astype(jnp.float32)
            est = stat.update(est, xc[c], vi)
        return (jax.vmap(stat.merge)(states, delta), est), None

    (states, est), _ = jax.lax.scan(body, (init, init_est),
                                    jnp.arange(nchunks, dtype=jnp.int32))
    return (states, est) if with_estimate else states


def sharded_fused_states(stat: Statistic, base_seed, x2: jax.Array, B: int,
                         mesh=None, data_axis: str = "data",
                         nshards: Optional[int] = None,
                         chunk: Optional[int] = None, step=0,
                         with_estimate: bool = False):
    """B-leading pytree of fused per-resample states for ``x2``, sharded
    over ``mesh``'s ``data_axis`` (the multi-device matrix-free hot path).

    Rows are split into ``nshards`` contiguous blocks (zero-padded tail,
    masked via per-shard n_valid); each shard generates its implicit
    Poisson(1) weights in-kernel from its own stream (see
    ``_shard_local_states`` for the (base_seed, shard, chunk) keying) and
    only the psum of the small per-resample states
    (``Statistic.psum_state``) crosses devices.

    ``mesh=None`` (with ``nshards`` given) evaluates the identical
    decomposition sequentially on one device — the oracle the mesh run is
    bit-equal to.  ``chunk`` streams each shard's local rows through
    fixed-size fused calls (the sharded analogue of ``bootstrap_chunked``);
    ``step`` offsets the stream counter so delta-maintenance extends draw
    fresh streams per extension.  They are mutually exclusive: the stream
    index (step + c)·nshards + shard would alias across (step, chunk)
    pairs, silently correlating resamples between extensions.

    ``with_estimate=True`` returns ``(states, est_state)`` where
    ``est_state`` is the unweighted full-sample estimate state accumulated
    in the SAME pass (shard-wise merge / psum mirrors the resample states,
    so mesh and sequential stay bitwise consistent) — the single-read
    estimate for the chunked and streaming drivers.
    """
    # Fail actionably BEFORE tracing: a non-mergeable statistic would
    # otherwise die deep inside shard_map/scan with a shape error (or,
    # worse, silently mis-combine per-shard states).
    if not getattr(stat, "mergeable", True):
        raise ValueError(
            f"sharded_fused_states requires a mergeable statistic, but "
            f"{type(stat).__name__} sets mergeable=False — its per-shard "
            "states cannot be merge/psum-combined.  Use the single-device "
            "bootstrap (backend='fused_rng' without mesh=/nshards=), or "
            "implement an associative merge and set mergeable=True")
    if mesh is not None:
        nshards = int(mesh.shape[data_axis])
    if nshards is None:
        raise ValueError("sharded_fused_states needs mesh= or nshards=")
    if chunk is not None and not (isinstance(step, int) and step == 0):
        raise ValueError("chunk= and step= are mutually exclusive (their "
                         "stream indices would alias; see docstring)")
    n, dim = x2.shape
    m = -(-n // nshards)                 # ceil: local rows per shard
    xp = jnp.pad(x2, ((0, nshards * m - n), (0, 0)))

    if mesh is None:
        states, est = None, None
        for i in range(nshards):
            nv = min(max(n - i * m, 0), m)
            si = _shard_local_states(stat, base_seed, xp[i * m:(i + 1) * m],
                                     B, i, nshards, nv, chunk=chunk,
                                     step=step, with_estimate=with_estimate)
            if with_estimate:
                si, ei = si
                est = ei if est is None else stat.merge(est, ei)
            states = si if states is None else \
                jax.vmap(stat.merge)(states, si)
        return (states, est) if with_estimate else states

    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map_compat
    shard_map, sm_kw = shard_map_compat()

    def shard_fn(x_local, seed, step_):
        i = jax.lax.axis_index(data_axis)
        nv = jnp.clip(n - i * m, 0, m)
        st = _shard_local_states(stat, seed, x_local, B, i, nshards, nv,
                                 chunk=chunk, step=step_,
                                 with_estimate=with_estimate)
        if with_estimate:
            st, est = st
            return (stat.psum_state(st, data_axis),
                    stat.psum_state(est, data_axis))
        return stat.psum_state(st, data_axis)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(data_axis, None), P(), P()),
                   out_specs=(P(), P()) if with_estimate else P(), **sm_kw)
    return fn(xp, jnp.asarray(base_seed, jnp.int32),
              jnp.asarray(step, jnp.int32))


def multinomial_counts(key: jax.Array, B: int, n: int,
                       resample_size: Optional[int] = None) -> jax.Array:
    """Exact multinomial bootstrap counts, shape (B, n) int32.

    Drawn as n' categorical draws per resample, histogrammed as ONE
    flattened (B·m,) scatter-add into the (B, n) zeros buffer — a single
    XLA scatter dispatch instead of B vmapped ones.
    """
    m = n if resample_size is None else int(resample_size)
    idx = jax.random.randint(key, (B, m), 0, n)            # (B, m) draws
    rows = jnp.broadcast_to(jnp.arange(B, dtype=idx.dtype)[:, None],
                            idx.shape)
    # 2-D scatter indices (not a flattened B·n offset, which would overflow
    # int32 once B·n >= 2^31): still one XLA scatter dispatch.
    return jnp.zeros((B, n), jnp.int32).at[rows, idx].add(1)


def poisson_weights(key: jax.Array, B: int, n: int,
                    dtype=jnp.float32) -> jax.Array:
    """Poisson(1) bootstrap weights, shape (B, n)."""
    return jax.random.poisson(key, 1.0, (B, n)).astype(dtype)


def weights_for(engine: str, key: jax.Array, B: int, n: int) -> jax.Array:
    if engine == "multinomial":
        return multinomial_counts(key, B, n).astype(jnp.float32)
    if engine == "poisson":
        return poisson_weights(key, B, n)
    raise ValueError(f"unknown bootstrap engine: {engine!r}")


# ----------------------------------------------------------------------------
# the resample loop
# ----------------------------------------------------------------------------
def bootstrap_thetas(values: jax.Array, stat: Statistic,
                     weights: jax.Array, use_kernel: bool = False
                     ) -> jax.Array:
    """Apply ``stat`` under every weight row.  Returns (B, ...) results."""
    x2 = _as_2d(values)
    dim = x2.shape[1]

    if use_kernel and stat.moment_powers is not None:
        # fused Pallas path: one (B,n)@(n,d) pass for all moments at once.
        from repro.kernels.weighted_stats import ops as ws_ops
        w_tot, s1, s2 = ws_ops.weighted_moments(weights, x2)
        states = jax.vmap(stat.from_moments)(w_tot, s1, s2)
        return jax.vmap(stat.finalize)(states)

    def one(w_row):
        return stat.finalize(stat.update(stat.init_state(dim), values, w_row))

    return jax.vmap(one)(weights)


def _fused_thetas(values: jax.Array, stat: Statistic, B: int,
                  key: jax.Array) -> jax.Array:
    """Matrix-free resample loop: moments via in-kernel RNG, (B, n) never
    built.  Falls back to materializing the same implicit weights for
    statistics without a moment decomposition."""
    states = fused_resample_states(stat, seed_from_key(key), _as_2d(values),
                                   B)
    return jax.vmap(stat.finalize)(states)


@partial(jax.jit,
         static_argnames=("stat", "B", "engine", "use_kernel", "backend",
                          "mesh", "data_axis"))
def _bootstrap_jit(values, key, params, stat, B, engine, use_kernel,
                   backend, mesh=None, data_axis="data"):
    # ``stat`` is the hashable spec; its array parameters (e.g. KMeansStep
    # centroids) arrive traced in ``params`` so Lloyd-style loops that pass
    # a fresh same-shaped Statistic per call hit this cache entry.
    stat = bind_params(stat, params)
    n = values.shape[0]
    if backend == "fused_rng":
        if mesh is not None:
            states = sharded_fused_states(stat, seed_from_key(key),
                                          _as_2d(values), B, mesh=mesh,
                                          data_axis=data_axis)
            thetas = jax.vmap(stat.finalize)(states)
        else:
            thetas = _fused_thetas(values, stat, B, key)
    else:
        w = weights_for(engine, key, B, n)
        thetas = bootstrap_thetas(values, stat, w, use_kernel=use_kernel)
    estimate = stat(values)
    return thetas, estimate


def _check_fused_backend(backend, engine, mesh):
    if backend not in (None, "fused_rng"):
        raise ValueError(f"unknown bootstrap backend: {backend!r}")
    if backend == "fused_rng" and engine != "poisson":
        raise ValueError("backend='fused_rng' requires the poisson engine "
                         "(in-kernel RNG draws iid Poisson(1) weights)")
    if mesh is not None and backend != "fused_rng":
        raise ValueError("mesh= requires backend='fused_rng' (the sharded "
                         "path psums fused states; materialized weights "
                         "would ship a (B, n) matrix across devices)")


def bootstrap(values: jax.Array, stat: Statistic, B: int, key: jax.Array,
              engine: str = "poisson", p: float = 1.0,
              use_kernel: bool = False, alpha: float = 0.05,
              backend: Optional[str] = None, mesh=None,
              data_axis: str = "data") -> BootstrapResult:
    """One full bootstrap pass: B resamples, result distribution, accuracy.

    ``p`` is the fraction of the population the sample represents — passed to
    ``stat.correct`` (paper §2.1) on both the estimate and the thetas.
    ``backend="fused_rng"`` runs the matrix-free pipeline (module
    docstring); adding ``mesh=`` (a jax.sharding.Mesh) shards the n axis
    over ``data_axis`` and psums the per-shard fused states — no weight or
    sample traffic crosses devices.
    """
    if not isinstance(stat, Statistic):
        raise TypeError("stat must be a reduce_api.Statistic")
    _check_fused_backend(backend, engine, mesh)
    spec, params = split_params(stat)
    thetas, estimate = _bootstrap_jit(values, key, params, spec, int(B),
                                      engine, bool(use_kernel), backend,
                                      mesh, data_axis)
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(estimate, p)
    return BootstrapResult(
        estimate=estimate,
        thetas=thetas,
        report=accuracy.report_for(thetas, alpha=alpha,
                                   num_groups=getattr(stat, "num_groups",
                                                      None)),
        B=int(B),
        n=int(values.shape[0]),
    )


# ----------------------------------------------------------------------------
# streaming / chunked variant (large samples that don't fit a (B,n) matrix)
# ----------------------------------------------------------------------------
def bootstrap_chunked(values: jax.Array, stat: Statistic, B: int,
                      key: jax.Array, chunk: int = 65536,
                      engine: str = "poisson", p: float = 1.0,
                      backend: Optional[str] = None, mesh=None,
                      data_axis: str = "data") -> BootstrapResult:
    """Scan over chunks of the sample, merging per-resample states.

    Only valid for mergeable statistics (all built-ins).  Poisson weights are
    drawn per chunk with a folded key, so the full (B, n) matrix never
    materializes — peak memory is (B, chunk), or O(B·d) / O(B·k·d) /
    O(B·d·nbins) with ``backend="fused_rng"`` (every built-in statistic has
    a fused path — see ``Statistic.fused_poisson_states``; the per-chunk
    weight matrix never materializes either).  Chunk seeds derive as
    ``offset_seed(base, i)`` so long streams can't wrap int32.

    With ``mesh=`` (fused backend only) each shard scans its LOCAL rows in
    ``chunk``-sized fused calls — streams keyed (base_seed, shard, chunk) —
    and the per-resample states psum once at the end; no weight or sample
    traffic crosses devices.
    """
    if engine != "poisson":
        raise ValueError("chunked bootstrap requires the poisson engine "
                         "(multinomial couples all chunks; see DESIGN.md §7)")
    _check_fused_backend(backend, engine, mesh)
    x = _as_2d(values)
    n, dim = x.shape

    if mesh is not None:
        states, est = sharded_fused_states(stat, seed_from_key(key), x, B,
                                           mesh=mesh, data_axis=data_axis,
                                           chunk=chunk, with_estimate=True)
    else:
        pad = (-n) % chunk
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        nchunks = xp.shape[0] // chunk
        xc = xp.reshape(nchunks, chunk, dim)

        init = jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B))
        init_est = stat.init_state(dim)
        base_seed = seed_from_key(key)  # one base; chunks offset by counter

        def body(carry, inp):
            states, est = carry
            i, xi = inp
            n_valid = jnp.minimum(chunk, n - i * chunk)  # last-chunk suffix
            # unweighted estimate rides the SAME pass over xi (the old
            # ``stat(values)`` was a second full read of the sample).
            vi = (jnp.arange(chunk) < n_valid).astype(jnp.float32)
            est = stat.update(est, xi, vi)
            if backend == "fused_rng":
                delta = fused_resample_states(
                    stat, offset_seed(base_seed, i), xi, B, n_valid=n_valid)
                return (jax.vmap(stat.merge)(states, delta), est), None
            w = poisson_weights(jax.random.fold_in(key, i), B, chunk) \
                * vi[None, :]
            new = jax.vmap(lambda s, wr: stat.update(s, xi, wr))(states, w)
            return (new, est), None

        (states, est), _ = jax.lax.scan(body, (init, init_est),
                                        (jnp.arange(nchunks), xc))
    thetas = jax.vmap(stat.finalize)(states)
    thetas = stat.correct(thetas, p)
    estimate = stat.correct(stat.finalize(est), p)
    return BootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.report_for(thetas,
                                   num_groups=getattr(stat, "num_groups",
                                                      None)),
        B=int(B), n=int(n),
    )
