"""Double-buffered streaming bootstrap over a ShardedStore — crash-safe.

``bootstrap_chunked`` assumes the sample is already device-resident; real
EARL runs start from a sharded on-disk store whose rows must cross the
host-to-device boundary first.  Done naively that serializes into
``transfer(chunk i) -> compute(chunk i) -> transfer(chunk i+1) -> ...``
and the wall time is the SUM of transfer and compute.  This driver
overlaps them:

* a host *prefetch thread* pulls fixed-size batches from
  ``ShardedStore.iter_batches(chunk)``, pads the ragged tail, and stages
  each one onto the device with ``jax.device_put`` (an async enqueue on
  the transfer stream) — by the time the main thread wants chunk i+1 its
  H2D copy has been running behind chunk i's compute;
* a bounded hand-off queue (depth 2) gives classic double buffering: one
  chunk in compute, one staged/in flight, and the producer blocks rather
  than staging the whole dataset;
* the main thread folds each staged chunk into the running per-resample
  states with ONE donated jitted update per chunk, so XLA reuses the
  state buffers in place and never blocks on the result until the
  trailing edge (``block_until_ready`` once, after the last dispatch).

The per-chunk update is the SAME math as ``bootstrap_chunked``'s scan
body — chunk i draws its implicit Poisson(1) weights from
``offset_seed(seed_from_key(key), i)`` and the unweighted estimate rides
the same pass — so the streamed result is bitwise identical to
``bootstrap_chunked(store.read_all(), ...)`` under the same
``(key, chunk)`` while peak device residency stays
O(B·d + chunk·d + queue_depth·chunk·d), independent of n.

Crash safety (three orthogonal mechanisms, all off by default):

* ``checkpoint=``/``checkpoint_every=``/``resume=`` — every k chunks the
  donated carry ``(states, est)`` plus a cursor (next chunk index, rows
  consumed, base seed, run fingerprint) is snapshotted atomically through
  ``CheckpointManager``.  Because chunk i's weights are keyed
  ``offset_seed(base_seed, i)`` and the fold is a left-merge in chunk
  order, a killed run resumed from the last checkpoint produces a result
  BITWISE equal to the uninterrupted run — and the resumed pass re-reads
  only the rows past the cursor (``iter_batches(start_row=...)`` skips
  whole splits without opening them).
* ``retry=``/``policy=`` — the prefetch thread reads through
  ``ft.ResilientStore``: per-split checksum + row-count validation,
  bounded retry with exponential backoff, per-read deadline.  Observed
  fault/retry counts surface in ``StreamReport.faults``.
* degradation — with ``policy.on_exhausted="degrade"``, a split that
  fails its whole retry budget is declared LOST mid-run: its rows enter
  the chunk as zeros with a zero ``valid_mask`` (chunk boundaries and
  weight streams stay aligned — the PR 6 masked-weight machinery, no
  recompute of surviving rows), and the final result is corrected by
  ``p·valid_rows/N`` so the CI widens honestly, exactly like the
  dedicated ``valid_mask`` oracle run.
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue as queue_mod
import threading
import time
import zlib
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import (BootstrapResult, fused_resample_states,
                                  offset_seed, seed_from_key)
from repro.core.reduce_api import Statistic, bind_params, split_params


@dataclasses.dataclass
class StreamReport:
    """Where the streamed run's wall time went (host-side view).

    ``stage_s`` is the prefetch thread's cumulative batch-preparation +
    ``device_put`` enqueue time; ``wait_s`` is how long the main thread
    sat idle on the hand-off queue (transfer-bound when large);
    ``dispatch_s`` is the main thread's per-chunk dispatch time plus the
    single trailing-edge ``block_until_ready`` (compute-bound when
    large).  Perfect overlap drives ``wall_s`` toward
    max(stage, compute) instead of their sum.

    Fault-tolerance accounting: ``checkpoint_s``/``n_checkpoints`` cost
    of the periodic snapshots, ``resumed_from_chunk`` where a resumed run
    picked up (None for a fresh run), ``faults`` the observed
    fault/retry counters of the resilient read path (None when no
    ``retry``/``policy`` was given), ``lost_splits`` splits degraded to
    masked zeros, ``valid_rows`` rows that actually contributed.
    """
    wall_s: float
    stage_s: float
    wait_s: float
    dispatch_s: float
    n_chunks: int
    rows: int
    checkpoint_s: float = 0.0
    n_checkpoints: int = 0
    resumed_from_chunk: Optional[int] = None
    faults: Optional[object] = None          # ft.FaultCounters
    lost_splits: Tuple[int, ...] = ()
    valid_rows: int = -1


@dataclasses.dataclass
class StreamingBootstrapResult(BootstrapResult):
    stream: StreamReport = None


@partial(jax.jit, static_argnames=("spec", "B"), donate_argnums=(0, 1))
def _stream_chunk_jit(states, est, xi, vi, base_seed, i, params, spec, B):
    """Fold ONE staged chunk into the running (states, est) carry.

    Identical math, operand layout and seed derivation as the
    ``bootstrap_chunked`` fused scan body (bitwise-equality contract);
    ``vi`` is the chunk's exact 0.0/1.0 validity mask — a plain prefix
    for the ragged tail (bit-identical to the historical n_valid path),
    with interior holes for rows of splits lost mid-run.  ``states``/
    ``est`` are donated so the carry is updated in place and the device
    never holds two copies.
    """
    stat = bind_params(spec, params)
    est = stat.update(est, xi, vi)
    delta = fused_resample_states(stat, offset_seed(base_seed, i), xi, B,
                                  valid_mask=vi)
    return jax.vmap(stat.merge)(states, delta), est


def _put_until(out_q, item, stop) -> bool:
    """Blocking put that aborts when the consumer signals ``stop`` — the
    producer must never deadlock on a full queue after the consumer died
    (the pre-fix failure mode: a poisoned chunk raised in the consumer
    and the prefetch thread blocked forever on ``put``)."""
    while not stop.is_set():
        try:
            out_q.put(item, timeout=0.05)
            return True
        except queue_mod.Full:
            continue
    return False


def _stage_batches(store, chunk: int, out_q, timings: dict, stop,
                   start_row: int = 0,
                   stop_row: Optional[int] = None) -> None:
    """Prefetch-thread body: read → pad → mask → ``device_put`` → enqueue.

    ``device_put`` returns as soon as the H2D copy is enqueued, so the
    transfer of chunk i+1 proceeds while the consumer computes on chunk
    i.  Batches from ``iter_batches`` can be zero-copy views of a split;
    the ``np.ascontiguousarray``/pad copy here also shields the store's
    buffers from the transfer machinery.  Exceptions are forwarded to
    the consumer rather than dying silently on this thread; ``stop``
    (set by the consumer's cleanup) aborts any blocked enqueue.

    Each item carries the chunk's 0/1 validity mask: zeros for the
    padded tail and for rows of splits the resilient read path declared
    lost (``invalid_row_ranges`` — known by yield time, because every
    split feeding a batch is read before the batch is assembled).

    ``stop_row`` bounds the pass to the store's first ``stop_row`` rows
    (the pinned-extent path over a growing durable log): the stream it
    yields — chunk boundaries, ragged tail, masks — is exactly what a
    store holding only those rows would yield, so every bitwise contract
    downstream is preserved.
    """
    stage_s = 0.0
    try:
        row0 = start_row
        for batch in store.iter_batches(chunk, start_row=start_row):
            if stop_row is not None and row0 >= stop_row:
                break
            t0 = time.perf_counter()
            xb = np.asarray(batch, np.float32)
            if xb.ndim == 1:
                xb = xb[:, None]
            if stop_row is not None and row0 + len(xb) > stop_row:
                xb = xb[:stop_row - row0]
            nb = len(xb)
            mask = np.zeros((chunk,), np.float32)
            mask[:nb] = 1.0
            for lo, hi in (store.invalid_row_ranges()
                           if hasattr(store, "invalid_row_ranges") else ()):
                a, b = max(lo, row0) - row0, min(hi, row0 + nb) - row0
                if a < b:
                    mask[a:b] = 0.0
            if nb < chunk:
                xb = np.concatenate(
                    [xb, np.zeros((chunk - nb,) + xb.shape[1:], xb.dtype)])
            else:
                xb = np.ascontiguousarray(xb)
            xd = jax.device_put(xb)
            md = jax.device_put(mask)
            stage_s += time.perf_counter() - t0
            row0 += nb
            if not _put_until(out_q, (xd, md, nb, int(mask.sum())), stop):
                return
        _put_until(out_q, None, stop)
    except BaseException as exc:                # noqa: BLE001 — forwarded
        _put_until(out_q, exc, stop)
    finally:
        timings["stage_s"] = stage_s


def run_fingerprint(spec, params, *extra: int,
                    content: Optional[int] = None) -> str:
    """Digest of everything a bitwise resume contract depends on: the
    statistic's structural spec AND its array parameters (a resumed run
    with different KMeans centroids is a different run) plus the caller's
    integer run knobs (B, chunk, base seed, extents, ...).  Shared by
    ``bootstrap_streaming``, ``EarlSession`` and ``LiveSession``
    checkpoints.

    ``content`` (optional) folds a data-content digest in — see
    ``store_content_digest``: with it, a resume against a store whose
    BYTES changed (same shape) fails the fingerprint check instead of
    silently folding new data under an old carry."""
    h = hashlib.sha256()
    h.update(repr(spec._static_key()).encode())
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        h.update(jax.tree_util.keystr(path).encode())
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    h.update(repr(tuple(int(e) for e in extra)).encode())
    if content is not None:
        h.update(b"content:" + repr(int(content)).encode())
    return h.hexdigest()


def store_content_digest(store, upto_row: Optional[int] = None) -> int:
    """Order-sensitive combination of every split's cached crc32 — the
    opt-in CONTENT half of the resume fingerprint.

    crc32 composes cheaply (one u32 per split, cached on the store after
    first computation), so this costs one pass over the store the first
    time and nothing after — which is exactly why it is opt-in
    (``fingerprint_content=True``) rather than default: hashing a
    bigger-than-memory store on every run would defeat streaming.

    ``upto_row`` restricts the digest to the splits that overlap the
    first ``upto_row`` rows — the pinned-extent path: over an append-only
    log those splits are immutable, so the digest of a prefix stays
    stable while the log grows."""
    h = 0
    for s in range(len(store.splits)):
        if upto_row is not None and int(store.offsets[s]) >= upto_row:
            break
        h = zlib.crc32(int(store.split_checksum(s)).to_bytes(4, "little"),
                       h)
    return h


def bootstrap_streaming(store, stat: Statistic, B: int, key: jax.Array,
                        chunk: int = 65536, p: float = 1.0,
                        alpha: float = 0.05,
                        backend: Optional[str] = "fused_rng",
                        queue_depth: int = 2,
                        checkpoint=None, checkpoint_every: int = 1,
                        resume: bool = False,
                        retry=None, policy=None,
                        fingerprint_content: bool = False,
                        n_rows: Optional[int] = None
                        ) -> StreamingBootstrapResult:
    """Streamed bootstrap over ``store`` (module docstring for the how).

    Matrix-free only: the point of streaming is that nothing of size n
    ever exists on either side of the PCIe link, which needs the
    ``backend="fused_rng"`` in-kernel weight generation.  Returns the
    usual ``BootstrapResult`` fields plus a ``StreamReport``; the result
    is bitwise equal to
    ``bootstrap_chunked(store.read_all(), stat, B, key, chunk=chunk,
    backend="fused_rng")``.

    Crash safety: ``checkpoint=`` (a ``CheckpointManager`` or a root
    path) snapshots the carry every ``checkpoint_every`` chunks;
    ``resume=True`` restores the latest snapshot (fingerprint-checked)
    and continues — bitwise equal to the uninterrupted run.  ``retry=``
    (an ``ft.RetryPolicy``) or ``policy=`` (an ``ft.FailurePolicy``,
    which also decides raise-vs-degrade on budget exhaustion) route the
    prefetch reads through ``ft.ResilientStore``.

    ``fingerprint_content=True`` additionally binds the checkpoint
    fingerprint to the store's BYTES (``store_content_digest`` over the
    cached per-split crc32s): a resume against a same-shape store whose
    contents changed refuses loudly instead of folding new data under an
    old carry.  Off by default — it costs one full read of the store the
    first time (checksums are cached after that), and both the save and
    the resume must opt in for the fingerprints to match.

    ``n_rows=`` pins the pass to the store's first ``n_rows`` rows — the
    stable-extent contract over a GROWING log (``IngestLog`` /
    ``DurableIngestLog``): the result, the fingerprint, the checkpoints
    and the ``p_eff`` denominator are all those of a store holding
    exactly those rows, so a resumed run keeps working (bitwise) even if
    a producer appended more batches in between.  Over an append-only
    log the pinned prefix is immutable, so ``fingerprint_content=True``
    composes with it (the digest covers only that prefix).
    """
    if not isinstance(stat, Statistic):
        raise TypeError("stat must be a reduce_api.Statistic")
    if backend != "fused_rng":
        raise ValueError(
            f"bootstrap_streaming is matrix-free only and got "
            f"backend={backend!r}; the only supported backend is "
            "'fused_rng' (a materialized (B, chunk) weight matrix would "
            "defeat the streaming memory contract)")
    # Fail BEFORE the prefetch thread starts: a non-mergeable statistic
    # cannot fold chunk i+1's delta states into chunk i's carry.
    if not getattr(stat, "mergeable", True):
        raise ValueError(
            f"bootstrap_streaming folds per-chunk states with merge(), but "
            f"{type(stat).__name__} sets mergeable=False — stream a "
            "mergeable statistic, or run the single-pass bootstrap "
            "(backend='fused_rng') on the materialized sample instead")
    if store.N == 0:
        raise ValueError("bootstrap_streaming needs a non-empty store")
    if n_rows is None:
        n_rows = int(store.N)
    elif not 0 < n_rows <= store.N:
        raise ValueError(f"n_rows must be in [1, {store.N}] "
                         f"(the store's current extent), got {n_rows}")
    else:
        n_rows = int(n_rows)
    if queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    if checkpoint_every < 1:
        raise ValueError(
            f"checkpoint_every must be >= 1, got {checkpoint_every}")
    if resume and checkpoint is None:
        raise ValueError("resume=True needs checkpoint= (where would the "
                         "cursor come from?)")
    head = store.splits[0]
    dim = int(np.prod(head.shape[1:])) if head.ndim > 1 else 1

    mgr = checkpoint
    if isinstance(mgr, str):
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(mgr, async_save=True)

    # resilient read path: policy wins over bare retry
    reader = store
    counters = None
    if policy is not None or retry is not None:
        from repro.ft.inject import FaultCounters, ResilientStore
        counters = FaultCounters()
        if policy is not None:
            reader = ResilientStore(store, policy.retry, counters,
                                    on_exhausted=policy.on_exhausted)
        else:
            reader = ResilientStore(store, retry, counters,
                                    on_exhausted="raise")

    spec, params = split_params(stat)
    base_seed = seed_from_key(key)
    seed_int = int(base_seed)
    fp = run_fingerprint(spec, params, B, chunk, seed_int, n_rows, dim,
                         content=(store_content_digest(store,
                                                       upto_row=n_rows)
                                  if fingerprint_content else None))

    # Fresh, UNALIASED device buffers for the donated carry: jnp's constant
    # cache can hand several identical-zeros leaves the same buffer, which
    # trips "attempt to donate the same buffer twice" on the first call.
    def _fresh(tree):
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(np.asarray(a)), tree)
    states = _fresh(jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B)))
    est = _fresh(stat.init_state(dim))

    start_chunk = 0
    rows_done = 0
    valid_rows = 0
    prior_lost: Tuple[int, ...] = ()
    resumed_from = None
    if resume:
        # validate the cursor BEFORE touching the arrays: a wrong-run
        # checkpoint must fail on the fingerprint, not a shape mismatch
        cur = mgr.meta().get("cursor")
        if cur is None:
            raise ValueError(
                f"checkpoint under {mgr.root} has no streaming cursor — "
                "not a bootstrap_streaming checkpoint")
        if cur["fingerprint"] != fp:
            raise ValueError(
                "checkpoint fingerprint mismatch: the snapshot was taken "
                "under a different (statistic, B, chunk, key, store) — "
                "resuming it would silently produce a different estimator "
                f"(checkpoint {cur['fingerprint'][:12]}…, run {fp[:12]}…)")
        template = jax.eval_shape(lambda: (states, est))
        (states, est), _ = mgr.restore(template)
        states, est = _fresh(states), _fresh(est)
        start_chunk = int(cur["next_chunk"])
        rows_done = int(cur["rows_done"])
        valid_rows = int(cur["valid_rows"])
        prior_lost = tuple(cur.get("lost_splits", ()))
        resumed_from = start_chunk

    q = queue_mod.Queue(maxsize=queue_depth)
    timings: dict = {}
    stop = threading.Event()
    producer = threading.Thread(target=_stage_batches,
                                args=(reader, chunk, q, timings, stop,
                                      rows_done, n_rows),
                                name="earl-stream-prefetch", daemon=True)
    t_start = time.perf_counter()
    producer.start()

    wait_s = dispatch_s = ckpt_s = 0.0
    n_ckpts = 0
    i = start_chunk

    def _save_checkpoint():
        # the cursor names the NEXT chunk; lost splits ride along so a
        # resumed run keeps correcting by the right surviving fraction.
        lost_now = tuple(sorted(set(prior_lost)
                                | set(getattr(reader, "lost_splits", ()))))
        mgr.save(i, (states, est), extra={"cursor": {
            "next_chunk": i, "rows_done": rows_done,
            "valid_rows": valid_rows, "lost_splits": list(lost_now),
            "fingerprint": fp, "B": int(B), "chunk": int(chunk),
            "base_seed": seed_int, "N": int(n_rows)}})

    try:
        while True:
            t0 = time.perf_counter()
            item = q.get()
            wait_s += time.perf_counter() - t0
            if item is None:
                break
            if isinstance(item, BaseException):
                raise item
            xd, md, nb, nv = item
            t0 = time.perf_counter()
            states, est = _stream_chunk_jit(
                states, est, xd, md, base_seed, jnp.asarray(i, jnp.int32),
                params, spec, int(B))
            dispatch_s += time.perf_counter() - t0
            rows_done += nb
            valid_rows += nv
            i += 1
            if mgr is not None and (i - start_chunk) % checkpoint_every == 0:
                t0 = time.perf_counter()
                _save_checkpoint()
                ckpt_s += time.perf_counter() - t0
                n_ckpts += 1
    finally:
        # a consumer-side failure (poisoned chunk, checkpoint error, kill)
        # must not strand the producer blocked on a full queue: signal
        # stop, drain whatever it already staged, and join it.
        stop.set()
        try:
            while True:
                q.get_nowait()
        except queue_mod.Empty:
            pass
        producer.join(timeout=10.0)

    # trailing-edge sync + a final checkpoint if the cadence missed the end
    t0 = time.perf_counter()
    (states, est) = jax.block_until_ready((states, est))
    dispatch_s += time.perf_counter() - t0
    if mgr is not None and (i - start_chunk) % checkpoint_every != 0:
        t0 = time.perf_counter()
        _save_checkpoint()
        ckpt_s += time.perf_counter() - t0
        n_ckpts += 1
    if mgr is not None:
        mgr.wait()                      # durable before we report success
    wall_s = time.perf_counter() - t_start

    lost = tuple(sorted(set(prior_lost)
                        | set(getattr(reader, "lost_splits", ()))))
    # the survivors represent p·(valid/N) of the population; with no loss
    # valid == N exactly and this is the plain p (ratio is exactly 1.0).
    p_eff = p * (valid_rows / n_rows)
    stat = bind_params(spec, params)
    thetas = stat.correct(jax.vmap(stat.finalize)(states), p_eff)
    estimate = stat.correct(stat.finalize(est), p_eff)
    return StreamingBootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.report_for(thetas, alpha=alpha,
                                   num_groups=getattr(stat, "num_groups",
                                                      None)),
        B=int(B), n=int(valid_rows),
        stream=StreamReport(wall_s=wall_s,
                            stage_s=timings.get("stage_s", 0.0),
                            wait_s=wait_s, dispatch_s=dispatch_s,
                            n_chunks=i - start_chunk, rows=int(n_rows),
                            checkpoint_s=ckpt_s, n_checkpoints=n_ckpts,
                            resumed_from_chunk=resumed_from,
                            faults=counters, lost_splits=lost,
                            valid_rows=int(valid_rows)),
    )
