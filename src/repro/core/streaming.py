"""Double-buffered streaming bootstrap over a ShardedStore.

``bootstrap_chunked`` assumes the sample is already device-resident; real
EARL runs start from a sharded on-disk store whose rows must cross the
host-to-device boundary first.  Done naively that serializes into
``transfer(chunk i) -> compute(chunk i) -> transfer(chunk i+1) -> ...``
and the wall time is the SUM of transfer and compute.  This driver
overlaps them:

* a host *prefetch thread* pulls fixed-size batches from
  ``ShardedStore.iter_batches(chunk)``, pads the ragged tail, and stages
  each one onto the device with ``jax.device_put`` (an async enqueue on
  the transfer stream) — by the time the main thread wants chunk i+1 its
  H2D copy has been running behind chunk i's compute;
* a bounded hand-off queue (depth 2) gives classic double buffering: one
  chunk in compute, one staged/in flight, and the producer blocks rather
  than staging the whole dataset;
* the main thread folds each staged chunk into the running per-resample
  states with ONE donated jitted update per chunk, so XLA reuses the
  state buffers in place and never blocks on the result until the
  trailing edge (``block_until_ready`` once, after the last dispatch).

The per-chunk update is the SAME math as ``bootstrap_chunked``'s scan
body — chunk i draws its implicit Poisson(1) weights from
``offset_seed(seed_from_key(key), i)`` and the unweighted estimate rides
the same pass — so the streamed result is bitwise identical to
``bootstrap_chunked(store.read_all(), ...)`` under the same
``(key, chunk)`` while peak device residency stays
O(B·d + chunk·d + queue_depth·chunk·d), independent of n.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy
from repro.core.bootstrap import (BootstrapResult, fused_resample_states,
                                  offset_seed, seed_from_key)
from repro.core.reduce_api import Statistic, bind_params, split_params


@dataclasses.dataclass
class StreamReport:
    """Where the streamed run's wall time went (host-side view).

    ``stage_s`` is the prefetch thread's cumulative batch-preparation +
    ``device_put`` enqueue time; ``wait_s`` is how long the main thread
    sat idle on the hand-off queue (transfer-bound when large);
    ``dispatch_s`` is the main thread's per-chunk dispatch time plus the
    single trailing-edge ``block_until_ready`` (compute-bound when
    large).  Perfect overlap drives ``wall_s`` toward
    max(stage, compute) instead of their sum.
    """
    wall_s: float
    stage_s: float
    wait_s: float
    dispatch_s: float
    n_chunks: int
    rows: int


@dataclasses.dataclass
class StreamingBootstrapResult(BootstrapResult):
    stream: StreamReport = None


@partial(jax.jit, static_argnames=("spec", "B", "chunk"),
         donate_argnums=(0, 1))
def _stream_chunk_jit(states, est, xi, base_seed, i, n_valid, params, spec,
                      B, chunk):
    """Fold ONE staged chunk into the running (states, est) carry.

    Identical math, operand layout and seed derivation as the
    ``bootstrap_chunked`` fused scan body (bitwise-equality contract);
    ``states``/``est`` are donated so the carry is updated in place and
    the device never holds two copies.
    """
    stat = bind_params(spec, params)
    vi = (jnp.arange(chunk) < n_valid).astype(jnp.float32)
    est = stat.update(est, xi, vi)
    delta = fused_resample_states(stat, offset_seed(base_seed, i), xi, B,
                                  n_valid=n_valid)
    return jax.vmap(stat.merge)(states, delta), est


def _stage_batches(store, chunk: int, out_q, timings: dict) -> None:
    """Prefetch-thread body: read → pad → ``device_put`` → enqueue.

    ``device_put`` returns as soon as the H2D copy is enqueued, so the
    transfer of chunk i+1 proceeds while the consumer computes on chunk
    i.  Batches from ``iter_batches`` can be zero-copy views of a split;
    the ``np.ascontiguousarray``/pad copy here also shields the store's
    buffers from the transfer machinery.  Exceptions are forwarded to
    the consumer rather than dying silently on this thread.
    """
    stage_s = 0.0
    try:
        for batch in store.iter_batches(chunk):
            t0 = time.perf_counter()
            xb = np.asarray(batch, np.float32)
            if xb.ndim == 1:
                xb = xb[:, None]
            nb = len(xb)
            if nb < chunk:
                xb = np.concatenate(
                    [xb, np.zeros((chunk - nb,) + xb.shape[1:], xb.dtype)])
            else:
                xb = np.ascontiguousarray(xb)
            xd = jax.device_put(xb)
            stage_s += time.perf_counter() - t0
            out_q.put((xd, nb))
        out_q.put(None)
    except BaseException as exc:                # noqa: BLE001 — forwarded
        out_q.put(exc)
    finally:
        timings["stage_s"] = stage_s


def bootstrap_streaming(store, stat: Statistic, B: int, key: jax.Array,
                        chunk: int = 65536, p: float = 1.0,
                        alpha: float = 0.05,
                        backend: Optional[str] = "fused_rng",
                        queue_depth: int = 2) -> StreamingBootstrapResult:
    """Streamed bootstrap over ``store`` (module docstring for the how).

    Matrix-free only: the point of streaming is that nothing of size n
    ever exists on either side of the PCIe link, which needs the
    ``backend="fused_rng"`` in-kernel weight generation.  Returns the
    usual ``BootstrapResult`` fields plus a ``StreamReport``; the result
    is bitwise equal to
    ``bootstrap_chunked(store.read_all(), stat, B, key, chunk=chunk,
    backend="fused_rng")``.
    """
    if not isinstance(stat, Statistic):
        raise TypeError("stat must be a reduce_api.Statistic")
    if backend != "fused_rng":
        raise ValueError(
            f"bootstrap_streaming is matrix-free only and got "
            f"backend={backend!r}; the only supported backend is "
            "'fused_rng' (a materialized (B, chunk) weight matrix would "
            "defeat the streaming memory contract)")
    # Fail BEFORE the prefetch thread starts: a non-mergeable statistic
    # cannot fold chunk i+1's delta states into chunk i's carry.
    if not getattr(stat, "mergeable", True):
        raise ValueError(
            f"bootstrap_streaming folds per-chunk states with merge(), but "
            f"{type(stat).__name__} sets mergeable=False — stream a "
            "mergeable statistic, or run the single-pass bootstrap "
            "(backend='fused_rng') on the materialized sample instead")
    if store.N == 0:
        raise ValueError("bootstrap_streaming needs a non-empty store")
    if queue_depth < 1:
        raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
    head = store.splits[0]
    dim = int(np.prod(head.shape[1:])) if head.ndim > 1 else 1

    spec, params = split_params(stat)
    base_seed = seed_from_key(key)
    # Fresh, UNALIASED device buffers for the donated carry: jnp's constant
    # cache can hand several identical-zeros leaves the same buffer, which
    # trips "attempt to donate the same buffer twice" on the first call.
    def _fresh(tree):
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(np.asarray(a)), tree)
    states = _fresh(jax.vmap(lambda _: stat.init_state(dim))(jnp.arange(B)))
    est = _fresh(stat.init_state(dim))

    q = queue_mod.Queue(maxsize=queue_depth)
    timings: dict = {}
    producer = threading.Thread(target=_stage_batches,
                                args=(store, chunk, q, timings),
                                name="earl-stream-prefetch", daemon=True)
    t_start = time.perf_counter()
    producer.start()

    wait_s = dispatch_s = 0.0
    i = 0
    while True:
        t0 = time.perf_counter()
        item = q.get()
        wait_s += time.perf_counter() - t0
        if item is None:
            break
        if isinstance(item, BaseException):
            raise item
        xd, nb = item
        t0 = time.perf_counter()
        states, est = _stream_chunk_jit(
            states, est, xd, base_seed, jnp.asarray(i, jnp.int32),
            jnp.asarray(nb, jnp.int32), params, spec, int(B), int(chunk))
        dispatch_s += time.perf_counter() - t0
        i += 1

    t0 = time.perf_counter()
    (states, est) = jax.block_until_ready((states, est))   # trailing edge
    dispatch_s += time.perf_counter() - t0
    wall_s = time.perf_counter() - t_start
    producer.join()

    stat = bind_params(spec, params)
    thetas = stat.correct(jax.vmap(stat.finalize)(states), p)
    estimate = stat.correct(stat.finalize(est), p)
    return StreamingBootstrapResult(
        estimate=estimate, thetas=thetas,
        report=accuracy.report_for(thetas, alpha=alpha,
                                   num_groups=getattr(stat, "num_groups",
                                                      None)),
        B=int(B), n=int(store.N),
        stream=StreamReport(wall_s=wall_s,
                            stage_s=timings.get("stage_s", 0.0),
                            wait_s=wait_s, dispatch_s=dispatch_s,
                            n_chunks=i, rows=int(store.N)),
    )
